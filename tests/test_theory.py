"""Paper theorems validated numerically (Prop 1/2, Cor 1, Thm 1, Thm 2)."""

import math

import numpy as np
import pytest

from repro.core.partition import balanced_partition
from repro.core.policies import BalancedSplitting, ModifiedBalancedSplitting
from repro.core.sim_jax import modified_bs_sim
from repro.core.simulator import simulate
from repro.core.theory import (analyze, p_helper_upper_bound,
                               theorem1_prelimit, theorem2_limit,
                               theorem2_prelimit)
from repro.core.workload import (critical_scaling, figure1_base_classes,
                                 figure1_workload, subcritical_scaling)


def test_eq16_matches_monte_carlo():
    """P_H^mod (eq. 16, Erlang) == simulated ModifiedBS-π blocking."""
    wl = figure1_workload(512, theta=0.7)
    bound = p_helper_upper_bound(wl)
    sim = modified_bs_sim(wl.sample_trace(200_000, seed=5), wl=wl)
    assert sim.p_helper == pytest.approx(bound, abs=0.01)


def test_proposition2_bs_below_modified():
    """Prop. 2 / Cor. 1: P_H(BS-π) <= P_H(ModifiedBS-π)."""
    wl = figure1_workload(512, theta=0.7)
    trace = wl.sample_trace(40_000, seed=7)
    from repro.core.simulator import simulate_trace
    bs = simulate_trace(trace, BalancedSplitting.for_workload(wl))
    mod = simulate_trace(trace, ModifiedBalancedSplitting.for_workload(wl))
    assert bs.p_helper <= mod.p_helper + 0.01


def test_theorem1_subcritical_ph_vanishes():
    """Thm 1: P_H -> 0 under scaling (7); the R -> Σ α_i d_i claim follows
    because A-system jobs are served immediately (response == service),
    which we check on the simulated sample path."""
    base = figure1_base_classes()
    lam = 0.85 / sum(c.alpha * c.d * c.n for c in base)  # load 0.85
    # f_k = 1 (pure many-server): slots ~ k, so Erlang blocking decays
    # exponentially; the paper's (k/32)^(2/3) growth also satisfies (6)
    # but its k^(1/3) slot growth converges only at astronomical k.
    one = lambda k: 1  # noqa: E731
    vals = [theorem1_prelimit(base, lam, k, fk=one)
            for k in (64, 256, 1024, 4096)]
    assert all(v2 <= v1 + 1e-12 for v1, v2 in zip(vals, vals[1:]))
    assert vals[-1] < 5e-4
    # sample path: accepted (A-system) jobs have zero wait exactly, and the
    # accepted-job mean response equals the zero-wait limit Σ α_i d_i
    wl = subcritical_scaling(base, lam, 4096, fk=one)
    trace = wl.sample_trace(100_000, seed=1)
    sim = modified_bs_sim(trace, wl=wl)
    accepted = ~sim.blocked
    assert accepted.mean() > 0.999
    resp_accepted = trace.service[accepted]           # wait == 0
    assert resp_accepted.mean() == pytest.approx(
        wl.zero_wait_response_time(), rel=0.02)


def test_theorem2_critical_rate():
    """Thm 2: √(k/f_k)·P_H^mod hovers at θ Σ (α_i/θ_i)φ(θ_i)/Φ(θ_i)
    (convergence is non-monotone due to the floor() integer effects in
    s_i and f_k, so we assert a band around the limit)."""
    base = figure1_base_classes()
    theta = 0.7
    limit = theorem2_limit(base, theta)
    for k in (4096, 32768, 262144):
        pre = theorem2_prelimit(base, theta, k)
        assert pre == pytest.approx(limit, rel=0.08), f"k={k}: {pre}"


def test_proposition1_stability_condition():
    """Eq. (5): the sufficient condition holds for large k in the
    subcritical regime (per-class blocking decays exponentially there —
    asymptotic throughput optimality)."""
    base = figure1_base_classes()
    lam = 0.85 / sum(c.alpha * c.d * c.n for c in base)
    one = lambda k: 1  # noqa: E731
    loads = []
    for k in (256, 1024, 4096):
        wl = subcritical_scaling(base, lam, k, fk=one)
        loads.append(analyze(wl).helper_load)
    assert loads[-1] < 1.0               # eq. (5) satisfied -> stable
    assert loads[-1] == min(loads)


@pytest.mark.slow
def test_bs_beats_fcfs_at_scale():
    """The paper's headline: in the critical regime at large k, BS-π beats
    FCFS on mean response time (Figure 1 ordering)."""
    wl = figure1_workload(2048, theta=0.7)
    trace = wl.sample_trace(60_000, seed=11)
    from repro.core.policies import FCFS
    from repro.core.simulator import simulate_trace
    bs = simulate_trace(trace, BalancedSplitting.for_workload(wl))
    fcfs = simulate_trace(trace, FCFS())
    assert bs.mean_response < fcfs.mean_response
