"""Erlang-B machinery: formula, recursion, Lemma-1 asymptotics, properties."""

import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.erlang import (erlang_b, erlang_b_array, erlang_b_jnp,
                               erlang_b_log, halfin_whitt_limit,
                               mean_response)


def erlang_direct(s, a):
    """Eq. (3) evaluated directly (small s only)."""
    terms = [a ** j / math.factorial(j) for j in range(s + 1)]
    return terms[-1] / sum(terms)


@pytest.mark.parametrize("s,a", [(1, 0.5), (4, 2.0), (10, 9.0), (20, 25.0)])
def test_recursion_matches_formula(s, a):
    assert erlang_b(s, a) == pytest.approx(erlang_direct(s, a), rel=1e-12)


def test_array_consistent():
    arr = erlang_b_array(50, 30.0)
    assert arr[0] == 1.0
    for s in (1, 10, 50):
        assert arr[s] == pytest.approx(erlang_b(s, 30.0), rel=1e-12)


def test_log_version():
    # subcritical large-s: E underflows but log stays finite
    lg = erlang_b_log(2000, 1000.0)
    assert -2000 < lg < -50
    assert erlang_b_log(10, 5.0) == pytest.approx(
        math.log(erlang_b(10, 5.0)), rel=1e-9)


def test_jnp_matches_numpy():
    v = float(erlang_b_jnp(64, 50.0))
    assert v == pytest.approx(erlang_b(64, 50.0), rel=1e-5)


def test_mean_response_eq4():
    # R_s = d (1 - E_s(λd))
    lam, d, s = 5.0, 2.0, 12
    assert mean_response(s, lam, d) == pytest.approx(
        d * (1 - erlang_b(s, lam * d)), rel=1e-12)


def test_lemma1_halfin_whitt_convergence():
    """√s·E_s(λd) -> φ(θ)/Φ(θ) under (1-ρ)√s -> θ."""
    theta = 0.7
    limit = halfin_whitt_limit(theta)
    errs = []
    for s in (100, 1000, 10000):
        a = s * (1 - theta / math.sqrt(s))
        errs.append(abs(math.sqrt(s) * erlang_b(s, a) - limit))
    assert errs[-1] < errs[0]
    assert errs[-1] < 0.02 * limit


@settings(max_examples=60, deadline=None)
@given(s=st.integers(1, 200), a=st.floats(0.01, 300.0))
def test_blocking_probability_in_unit_interval(s, a):
    e = erlang_b(s, a)
    assert 0.0 <= e <= 1.0


@settings(max_examples=40, deadline=None)
@given(s=st.integers(1, 100), a=st.floats(0.1, 120.0))
def test_monotone_decreasing_in_servers(s, a):
    assert erlang_b(s + 1, a) <= erlang_b(s, a) + 1e-15


@settings(max_examples=40, deadline=None)
@given(s=st.integers(1, 100), a=st.floats(0.1, 100.0),
       da=st.floats(0.01, 10.0))
def test_monotone_increasing_in_load(s, a, da):
    assert erlang_b(s, a + da) >= erlang_b(s, a) - 1e-15


def test_erlang_vs_loss_queue_simulation():
    """Property 1 building block: M/M/s/s sample path vs Erlang-B."""
    from repro.core.sim_jax import loss_queue_sim
    lam, d, s, n = 8.0, 1.0, 10, 200_000
    rng = np.random.default_rng(3)
    arrival = np.cumsum(rng.exponential(1 / lam, n))
    service = rng.exponential(d, n)
    res = loss_queue_sim(arrival, service, s)
    assert res.blocked.mean() == pytest.approx(
        erlang_b(s, lam * d), abs=0.01)
