"""Gang scheduler (BS-π on a fleet): invariants, cross-validation with the
queueing simulator, elastic repartition, straggler mitigation."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.policies import BalancedSplitting
from repro.core.simulator import simulate_trace
from repro.core.workload import Exp, JobClass, Workload, figure1_workload
from repro.sched.cluster import BalancedMeshPartition
from repro.sched.elastic import elastic_repartition
from repro.sched.gang import GangJob, GangScheduler, simulate_gangs
from repro.runtime.straggler import StragglerMitigator


def jobs_from_trace(trace):
    return [GangJob(jid=i, cls=int(trace.cls[i]), need=int(trace.need[i]),
                    arrival=float(trace.arrival[i]),
                    service=float(trace.service[i]))
            for i in range(trace.num_jobs)]


def test_partition_matches_core():
    wl = figure1_workload(512, theta=0.7)
    mp = BalancedMeshPartition.build(wl.k, wl.classes)
    mp.validate()
    core = mp.as_core_partition()
    core.validate()
    from repro.core.partition import balanced_partition
    ref = balanced_partition(wl)
    assert core.a == ref.a and core.psi == pytest.approx(ref.psi)


def test_gang_scheduler_matches_bs_policy():
    """Event-for-event: GangScheduler response times == BS-π policy in the
    reference simulator on the same trace (helper = contiguous first-fit,
    matched by using single-chip-need jobs where fragmentation can't
    differ)."""
    classes = (JobClass("a", 1, Exp(1.0), 0.6),
               JobClass("b", 1, Exp(3.0), 0.4))
    wl = Workload(k=16, lam=1.0, classes=classes).with_load(0.85)
    trace = wl.sample_trace(4000, seed=3)
    ref = simulate_trace(trace, BalancedSplitting.for_workload(wl))
    mp = BalancedMeshPartition.build(wl.k, wl.classes)
    sched = simulate_gangs(mp, jobs_from_trace(trace))
    resp = np.array([j.finish - j.arrival for j in sched.completed])
    assert resp.mean() == pytest.approx(ref.mean_response, rel=1e-9)
    assert sched.p_helper == pytest.approx(ref.p_helper, abs=1e-12)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), load=st.floats(0.4, 0.9))
def test_gang_scheduler_invariants(seed, load):
    classes = (JobClass("s", 2, Exp(1.0), 0.7), JobClass("l", 8, Exp(4.0),
                                                         0.3))
    wl = Workload(k=64, lam=1.0, classes=classes).with_load(load)
    trace = wl.sample_trace(800, seed=seed)
    mp = BalancedMeshPartition.build(wl.k, wl.classes)
    sched = simulate_gangs(mp, jobs_from_trace(trace))
    assert len(sched.completed) == trace.num_jobs
    assert sched.helper_free == mp.helper.size          # all released
    assert all(len(f) == s.slots
               for f, s in zip(sched.free_slots, mp.slices))
    for j in sched.completed:
        assert j.finish >= j.start >= j.arrival


def test_elastic_repartition_chip_loss():
    wl = figure1_workload(512, theta=0.7)
    mp = BalancedMeshPartition.build(wl.k, wl.classes)
    sched = GangScheduler(mp)
    # occupy one slot of class 0
    j = GangJob(jid=0, cls=0, need=mp.slices[0].need, arrival=0.0,
                service=10.0)
    sched.arrive(j, 0.0)
    new_sched, report = elastic_repartition(sched, 384)
    assert report.new_k == 384
    new_sched.partition.validate()
    # the running gang survived (slot 0 exists in the smaller partition)
    assert 0 in new_sched.running
    # new partition is exactly what eq. (2) gives for 384 chips
    ref = BalancedMeshPartition.build(384, wl.classes)
    assert ref.slices == new_sched.partition.slices


def _saturated_two_class_sched():
    """Both slices and the helper block packed with long-running gangs, so
    every further need-4 arrival lands in ``helper_wait``.  Class a has
    mean service 1.0 (deadline 2.0 at multiple=2), class b 10.0 (20.0)."""
    classes = (JobClass("a", 4, Exp(1.0), 0.5), JobClass("b", 4, Exp(10.0),
                                                         0.5))
    mp = BalancedMeshPartition.build(32, classes)
    sched = GangScheduler(mp)
    jid = 0
    for c, sl in enumerate(mp.slices):
        for _ in range(sl.slots):
            sched.arrive(GangJob(jid, c, sl.need, 0.0, 1e3), 0.0)
            jid += 1
    for _ in range(mp.helper.size // 4):
        sched.arrive(GangJob(jid, 0, 4, 0.0, 1e3), 0.0)
        jid += 1
    assert not sched.helper_wait and sched.helper_free < 4
    return sched, jid


def test_straggler_promotion_fcfs_among_peers():
    """Deadline-blown gangs move ahead of patient ones but keep their own
    arrival order (π stays FCFS among the promoted peers)."""
    sched, jid = _saturated_two_class_sched()
    slow = GangJob(jid, 1, 4, 0.0, 1.0)       # class b: deadline 20, safe
    fast1 = GangJob(jid + 1, 0, 4, 1.0, 1.0)  # class a: deadline 2, blown
    fast2 = GangJob(jid + 2, 0, 4, 2.0, 1.0)  # class a: blown, arrived later
    for j in (slow, fast1, fast2):
        sched.arrive(j, j.arrival)
    assert [j.jid for j in sched.helper_wait] == [slow.jid, fast1.jid,
                                                  fast2.jid]
    mit = StragglerMitigator(sched, deadline_multiple=2.0)
    assert mit.tick(now=10.0) == 2
    assert [j.jid for j in sched.helper_wait] == [fast1.jid, fast2.jid,
                                                  slow.jid]
    assert mit.redirected == 2


def test_straggler_tick_schedules_only_on_promotion():
    """``_helper_schedule`` runs iff something was promoted — an idle tick
    must not touch the queue (or pay the schedule pass)."""
    sched, jid = _saturated_two_class_sched()
    sched.arrive(GangJob(jid, 0, 4, 1.0, 1.0), 1.0)
    calls = []
    orig = sched._helper_schedule
    sched._helper_schedule = lambda now: (calls.append(now), orig(now))[1]
    mit = StragglerMitigator(sched, deadline_multiple=2.0)
    assert mit.tick(now=1.5) == 0          # wait 0.5 < deadline 2.0
    assert calls == [] and mit.redirected == 0
    assert mit.tick(now=10.0) == 1         # wait 9.0 > deadline 2.0
    assert calls == [10.0] and mit.redirected == 1


def test_straggler_promotion():
    classes = (JobClass("a", 4, Exp(1.0), 0.5), JobClass("b", 4, Exp(1.0),
                                                         0.5))
    mp = BalancedMeshPartition.build(16, classes)
    sched = GangScheduler(mp)
    # fill everything so new arrivals queue
    jid = 0
    for _ in range(mp.slices[0].slots + mp.slices[1].slots +
                   mp.helper.size // 4):
        sched.arrive(GangJob(jid, jid % 2, 4, 0.0, 100.0), 0.0)
        jid += 1
    old = GangJob(jid, 0, 4, 0.0, 1.0)
    sched.arrive(old, 0.0)
    fresh = GangJob(jid + 1, 1, 4, 9.5, 1.0)
    sched.arrive(fresh, 9.5)
    assert list(sched.helper_wait)[0] is old
    mit = StragglerMitigator(sched, deadline_multiple=2.0)
    promoted = mit.tick(now=10.0)       # old blew its 2x1.0s deadline
    assert promoted >= 1
    assert list(sched.helper_wait)[0].jid == old.jid
