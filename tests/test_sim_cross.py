"""Event-for-event cross-validation of the lax.scan simulators.

The contract promised by the ``sim_jax`` module docstring: every scan
simulator (and its batched vmap variant) reproduces the Python
event-driven engine's sample path exactly — same start times, same
responses, same blocking decisions — on the traces both can run.  Also
pins the O(k) sorted-invariant FCFS step bit-for-bit to the retained
full-sort reference step.
"""

import heapq

import numpy as np
import pytest

import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import sim_jax
from repro.core.policies import make_policy
from repro.core.sim_batch import (bs_sim_batch, fcfs_sim_batch,
                                  loss_queue_sim_batch, modified_bs_sim_batch)
from repro.core.sim_jax import bs_sim, fcfs_sim, loss_queue_sim, \
    modified_bs_sim
from repro.core.simulator import Simulation
from repro.core.workload import Exp, JobClass, Workload, figure1_workload


def small_workload(k=24, load=0.85):
    classes = (
        JobClass("s", 1, Exp(1.0), 0.7),
        JobClass("m", 4, Exp(4.0), 0.2),
        JobClass("l", 8, Exp(8.0), 0.1),
    )
    return Workload(k=k, lam=1.0, classes=classes).with_load(load)


# -- loss queue ---------------------------------------------------------------


def loss_queue_reference(arrival, service, s):
    """Tiny event-driven M/GI/s/s oracle: heap of completion times."""
    comp: list[float] = []
    blocked = np.zeros(len(arrival), dtype=bool)
    for j, (t, svc) in enumerate(zip(arrival, service)):
        while comp and comp[0] <= t:
            heapq.heappop(comp)
        if len(comp) >= s:
            blocked[j] = True
        else:
            heapq.heappush(comp, t + svc)
    return blocked


def test_loss_queue_event_for_event(rng):
    n, s, lam = 5000, 6, 5.0
    arrival = np.cumsum(rng.exponential(1 / lam, n))
    service = rng.exponential(1.0, n)
    res = loss_queue_sim(arrival, service, s)
    ref = loss_queue_reference(arrival, service, s)
    assert np.array_equal(res.blocked, ref)


def test_loss_queue_batched_matches_single(rng):
    R, n, s = 3, 2000, 5
    arrival = np.cumsum(rng.exponential(0.25, (R, n)), axis=1)
    service = rng.exponential(1.0, (R, n))
    batch = loss_queue_sim_batch(arrival, service, s)
    for r in range(R):
        single = loss_queue_sim(arrival[r], service[r], s)
        assert np.array_equal(batch.blocked[r], single.blocked)
        assert np.array_equal(batch.response[r], single.response)


# -- FCFS ---------------------------------------------------------------------


def test_fcfs_event_for_event_vs_python_engine():
    wl = small_workload()
    trace = wl.sample_trace(4000, seed=3)
    sim = Simulation(trace, make_policy("fcfs"))
    sim.run()
    jx = fcfs_sim(trace)
    starts = jx.response + trace.arrival - trace.service
    np.testing.assert_allclose(starts, sim.start_time, rtol=1e-12, atol=1e-9)
    np.testing.assert_allclose(jx.response, sim.completion - trace.arrival,
                               rtol=1e-12, atol=1e-9)


def test_fcfs_batched_matches_single():
    wl = small_workload()
    batch = wl.sample_traces(2000, 3, seed=11)
    b = fcfs_sim_batch(batch)
    for r in range(batch.reps):
        single = fcfs_sim(batch.rep(r))
        assert np.array_equal(b.response[r], single.response)


def test_fcfs_sorted_step_bitexact_vs_sort_reference():
    """The O(k) roll-and-insert must equal the O(k log k) sort step
    bit-for-bit, including tied arrivals and zero service times."""
    rng = np.random.default_rng(12)
    for k, n_jobs in ((8, 500), (64, 2000), (256, 2000)):
        arrival = np.cumsum(rng.exponential(0.05, n_jobs))
        arrival[1::7] = arrival[0::7][: len(arrival[1::7])]  # inject ties
        arrival = np.sort(arrival)
        need = rng.integers(1, max(2, k // 4), size=n_jobs)
        service = np.where(rng.random(n_jobs) < 0.2, 0.0,
                           rng.exponential(1.0, n_jobs))
        with enable_x64():
            args = (jnp.asarray(arrival, jnp.float64),
                    jnp.asarray(need, jnp.int32),
                    jnp.asarray(service, jnp.float64), k)
            fast = np.asarray(sim_jax._fcfs_scan(*args))
            ref = np.asarray(sim_jax._fcfs_scan_reference(*args))
        assert np.array_equal(fast, ref), f"k={k}"


def test_fcfs_full_need_jobs():
    """Jobs needing all k servers exercise the p == 0 insertion edge."""
    k = 8
    arrival = np.arange(20, dtype=np.float64) * 0.1
    need = np.full(20, k, dtype=np.int64)
    service = np.full(20, 1.0)
    with enable_x64():
        args = (jnp.asarray(arrival), jnp.asarray(need, jnp.int32),
                jnp.asarray(service), k)
        fast = np.asarray(sim_jax._fcfs_scan(*args))
        ref = np.asarray(sim_jax._fcfs_scan_reference(*args))
    assert np.array_equal(fast, ref)
    # serial system: job j starts when job j-1 completes
    np.testing.assert_allclose(fast, np.arange(20) * 1.0 + arrival[0])


# -- ModifiedBS-FCFS ----------------------------------------------------------


def test_modbs_event_for_event_vs_python_engine():
    wl = figure1_workload(256, theta=0.7)
    trace = wl.sample_trace(4000, seed=4)
    sim = Simulation(trace, make_policy("modbs", wl=wl))
    py = sim.run()
    jx = modified_bs_sim(trace, wl=wl)
    np.testing.assert_allclose(jx.response, sim.completion - trace.arrival,
                               rtol=1e-12, atol=1e-9)
    assert py.p_helper == pytest.approx(jx.p_helper, abs=1e-12)


def test_modbs_batched_matches_single():
    wl = figure1_workload(256, theta=0.7)
    batch = wl.sample_traces(2000, 3, seed=13)
    b = modified_bs_sim_batch(batch, wl=wl)
    for r in range(batch.reps):
        single = modified_bs_sim(batch.rep(r), wl=wl)
        assert np.array_equal(b.response[r], single.response)
        assert float(b.p_helper[r]) == single.p_helper
        assert np.array_equal(b.blocked[r], single.blocked)


# -- BS-FCFS proper (Definition 1, rule-3 pull-backs) -------------------------


@pytest.mark.slow
@pytest.mark.parametrize("k", [32, 256])
def test_bs_event_for_event_vs_python_engine(k):
    """The event-indexed 2J-step scan must reproduce the (fixed) Python
    engine's BS-π sample path bit-for-bit — starts, responses, and both
    helper observables — on the Fig.-1 critical workload."""
    wl = figure1_workload(k, theta=0.7)
    trace = wl.sample_trace(4000, seed=3)
    pol = make_policy("bs", wl=wl)
    sim = Simulation(trace, pol)
    sim.run()
    jx = bs_sim(trace, wl=wl)
    # rtol=0: every scan start time is a max/selection over the same event
    # times the engine computes (never a new rounding), and both sides
    # derive response via the identical (start + service) - arrival float
    # ops — starts and responses match bit-for-bit
    assert np.array_equal(jx.start, sim.start_time)
    assert np.array_equal(jx.response, sim.completion - trace.arrival)
    assert jx.p_helper == pol.p_helper_estimate
    assert jx.p_routed == pol.p_routed_estimate


def test_bs_pullbacks_happen_and_differ_from_modbs():
    """Sanity that the cross-validation above exercises rule 3: pull-backs
    occur (served < routed) and the BS path differs from ModifiedBS."""
    wl = figure1_workload(64, theta=0.7)
    trace = wl.sample_trace(3000, seed=5)
    bs = bs_sim(trace, wl=wl)
    mod = modified_bs_sim(trace, wl=wl)
    assert bs.p_helper < bs.p_routed            # some jobs were pulled back
    assert not np.array_equal(bs.response, mod.response)
    assert bs.response.mean() <= mod.response.mean()


@pytest.mark.slow
def test_bs_batched_matches_single():
    wl = figure1_workload(256, theta=0.7)
    batch = wl.sample_traces(2000, 3, seed=13)
    b = bs_sim_batch(batch, wl=wl)
    for r in range(batch.reps):
        single = bs_sim(batch.rep(r), wl=wl)
        assert np.array_equal(b.response[r], single.response)
        assert float(b.p_helper[r]) == single.p_helper
        assert float(b.p_routed[r]) == single.p_routed


def test_bs_queue_cap_overflow_raises():
    """A too-small ring buffer must raise, never silently corrupt."""
    wl = figure1_workload(64, theta=0.7)
    trace = wl.sample_trace(3000, seed=7)
    with pytest.raises(RuntimeError, match="overflow"):
        bs_sim(trace, wl=wl, queue_cap=4)
