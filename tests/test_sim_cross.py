"""Event-for-event cross-validation of the lax.scan simulators.

The contract promised by the ``sim_jax`` module docstring: every scan
simulator (and its batched vmap variant) reproduces the Python
event-driven engine's sample path exactly — same start times, same
responses, same blocking decisions — on the traces both can run.  Also
pins the O(k) sorted-invariant FCFS step bit-for-bit to the retained
full-sort reference step, and the fused Pallas kernels
(``repro.kernels.msj_scan``, interpret mode on CPU) bit-for-bit (rtol=0)
to the jax-batch scan cores at k ∈ {32, 256} — including the preemptive
``sf-srpt``/``ff-srpt`` kernels, whose in-kernel stable bitonic
rank/permute network is additionally property-tested against
``jax.lax.sort(..., is_stable=True)`` on adversarial key sets.
"""

import heapq

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import sim_jax
from repro.core.policies import make_policy
from repro.core.sim_batch import (bs_sim_batch, fcfs_sim_batch,
                                  loss_queue_sim_batch, modified_bs_sim_batch)
from repro.core.sim_jax import bs_sim, fcfs_sim, loss_queue_sim, \
    modified_bs_sim
from repro.core.simulator import Simulation
from repro.core.workload import Exp, JobClass, Workload, figure1_workload


def small_workload(k=24, load=0.85):
    classes = (
        JobClass("s", 1, Exp(1.0), 0.7),
        JobClass("m", 4, Exp(4.0), 0.2),
        JobClass("l", 8, Exp(8.0), 0.1),
    )
    return Workload(k=k, lam=1.0, classes=classes).with_load(load)


# -- loss queue ---------------------------------------------------------------


def loss_queue_reference(arrival, service, s):
    """Tiny event-driven M/GI/s/s oracle: heap of completion times."""
    comp: list[float] = []
    blocked = np.zeros(len(arrival), dtype=bool)
    for j, (t, svc) in enumerate(zip(arrival, service)):
        while comp and comp[0] <= t:
            heapq.heappop(comp)
        if len(comp) >= s:
            blocked[j] = True
        else:
            heapq.heappush(comp, t + svc)
    return blocked


def test_loss_queue_event_for_event(rng):
    n, s, lam = 5000, 6, 5.0
    arrival = np.cumsum(rng.exponential(1 / lam, n))
    service = rng.exponential(1.0, n)
    res = loss_queue_sim(arrival, service, s)
    ref = loss_queue_reference(arrival, service, s)
    assert np.array_equal(res.blocked, ref)


def test_loss_queue_batched_matches_single(rng):
    R, n, s = 3, 2000, 5
    arrival = np.cumsum(rng.exponential(0.25, (R, n)), axis=1)
    service = rng.exponential(1.0, (R, n))
    batch = loss_queue_sim_batch(arrival, service, s)
    for r in range(R):
        single = loss_queue_sim(arrival[r], service[r], s)
        assert np.array_equal(batch.blocked[r], single.blocked)
        assert np.array_equal(batch.response[r], single.response)


# -- FCFS ---------------------------------------------------------------------


def test_fcfs_event_for_event_vs_python_engine():
    wl = small_workload()
    trace = wl.sample_trace(4000, seed=3)
    sim = Simulation(trace, make_policy("fcfs"))
    sim.run()
    jx = fcfs_sim(trace)
    starts = jx.response + trace.arrival - trace.service
    np.testing.assert_allclose(starts, sim.start_time, rtol=1e-12, atol=1e-9)
    np.testing.assert_allclose(jx.response, sim.completion - trace.arrival,
                               rtol=1e-12, atol=1e-9)


def test_fcfs_batched_matches_single():
    wl = small_workload()
    batch = wl.sample_traces(2000, 3, seed=11)
    b = fcfs_sim_batch(batch)
    for r in range(batch.reps):
        single = fcfs_sim(batch.rep(r))
        assert np.array_equal(b.response[r], single.response)


def test_fcfs_sorted_step_bitexact_vs_sort_reference():
    """The O(k) roll-and-insert must equal the O(k log k) sort step
    bit-for-bit, including tied arrivals and zero service times."""
    rng = np.random.default_rng(12)
    for k, n_jobs in ((8, 500), (64, 2000), (256, 2000)):
        arrival = np.cumsum(rng.exponential(0.05, n_jobs))
        arrival[1::7] = arrival[0::7][: len(arrival[1::7])]  # inject ties
        arrival = np.sort(arrival)
        need = rng.integers(1, max(2, k // 4), size=n_jobs)
        service = np.where(rng.random(n_jobs) < 0.2, 0.0,
                           rng.exponential(1.0, n_jobs))
        with enable_x64():
            args = (jnp.asarray(arrival, jnp.float64),
                    jnp.asarray(need, jnp.int32),
                    jnp.asarray(service, jnp.float64), k)
            fast = np.asarray(sim_jax._fcfs_scan(*args))
            ref = np.asarray(sim_jax._fcfs_scan_reference(*args))
        assert np.array_equal(fast, ref), f"k={k}"


def test_fcfs_full_need_jobs():
    """Jobs needing all k servers exercise the p == 0 insertion edge."""
    k = 8
    arrival = np.arange(20, dtype=np.float64) * 0.1
    need = np.full(20, k, dtype=np.int64)
    service = np.full(20, 1.0)
    with enable_x64():
        args = (jnp.asarray(arrival), jnp.asarray(need, jnp.int32),
                jnp.asarray(service), k)
        fast = np.asarray(sim_jax._fcfs_scan(*args))
        ref = np.asarray(sim_jax._fcfs_scan_reference(*args))
    assert np.array_equal(fast, ref)
    # serial system: job j starts when job j-1 completes
    np.testing.assert_allclose(fast, np.arange(20) * 1.0 + arrival[0])


# -- ModifiedBS-FCFS ----------------------------------------------------------


def test_modbs_event_for_event_vs_python_engine():
    wl = figure1_workload(256, theta=0.7)
    trace = wl.sample_trace(4000, seed=4)
    sim = Simulation(trace, make_policy("modbs", wl=wl))
    py = sim.run()
    jx = modified_bs_sim(trace, wl=wl)
    np.testing.assert_allclose(jx.response, sim.completion - trace.arrival,
                               rtol=1e-12, atol=1e-9)
    assert py.p_helper == pytest.approx(jx.p_helper, abs=1e-12)


def test_modbs_batched_matches_single():
    wl = figure1_workload(256, theta=0.7)
    batch = wl.sample_traces(2000, 3, seed=13)
    b = modified_bs_sim_batch(batch, wl=wl)
    for r in range(batch.reps):
        single = modified_bs_sim(batch.rep(r), wl=wl)
        assert np.array_equal(b.response[r], single.response)
        assert float(b.p_helper[r]) == single.p_helper
        assert np.array_equal(b.blocked[r], single.blocked)


# -- BS-FCFS proper (Definition 1, rule-3 pull-backs) -------------------------


@pytest.mark.slow
@pytest.mark.parametrize("k", [32, 256])
def test_bs_event_for_event_vs_python_engine(k):
    """The event-indexed 2J-step scan must reproduce the (fixed) Python
    engine's BS-π sample path bit-for-bit — starts, responses, and both
    helper observables — on the Fig.-1 critical workload."""
    wl = figure1_workload(k, theta=0.7)
    trace = wl.sample_trace(4000, seed=3)
    pol = make_policy("bs", wl=wl)
    sim = Simulation(trace, pol)
    sim.run()
    jx = bs_sim(trace, wl=wl)
    # rtol=0: every scan start time is a max/selection over the same event
    # times the engine computes (never a new rounding), and both sides
    # derive response via the identical (start + service) - arrival float
    # ops — starts and responses match bit-for-bit
    assert np.array_equal(jx.start, sim.start_time)
    assert np.array_equal(jx.response, sim.completion - trace.arrival)
    assert jx.p_helper == pol.p_helper_estimate
    assert jx.p_routed == pol.p_routed_estimate


def test_bs_pullbacks_happen_and_differ_from_modbs():
    """Sanity that the cross-validation above exercises rule 3: pull-backs
    occur (served < routed) and the BS path differs from ModifiedBS."""
    wl = figure1_workload(64, theta=0.7)
    trace = wl.sample_trace(3000, seed=5)
    bs = bs_sim(trace, wl=wl)
    mod = modified_bs_sim(trace, wl=wl)
    assert bs.p_helper < bs.p_routed            # some jobs were pulled back
    assert not np.array_equal(bs.response, mod.response)
    assert bs.response.mean() <= mod.response.mean()


@pytest.mark.slow
def test_bs_batched_matches_single():
    wl = figure1_workload(256, theta=0.7)
    batch = wl.sample_traces(2000, 3, seed=13)
    b = bs_sim_batch(batch, wl=wl)
    for r in range(batch.reps):
        single = bs_sim(batch.rep(r), wl=wl)
        assert np.array_equal(b.response[r], single.response)
        assert float(b.p_helper[r]) == single.p_helper
        assert float(b.p_routed[r]) == single.p_routed


def test_bs_queue_cap_overflow_raises():
    """A too-small ring buffer must raise, never silently corrupt."""
    wl = figure1_workload(64, theta=0.7)
    trace = wl.sample_trace(3000, seed=7)
    with pytest.raises(RuntimeError, match="overflow"):
        bs_sim(trace, wl=wl, queue_cap=4)


# -- fused Pallas kernels (interpret mode on CPU) -----------------------------
#
# The rtol=0 contract of the msj_scan kernel family: grid cell r runs the
# *same* step functions as the jax-batch scan cores (see sim_jax's
# "Fused-kernel layer" docstring), so starts/waits/observables must be
# bit-identical, not merely close.  The test iterates the engine registry,
# so a newly registered (policy, engine) pair is cross-validated the
# moment it registers — no hand-written pair list to forget to extend.


@pytest.mark.parametrize("k", [32, 256])
def test_registry_fast_engines_bitexact_vs_jax(k):
    from repro.core import engines

    wl = figure1_workload(k, theta=0.7)
    batch = wl.sample_traces(1200, 2, seed=17)
    # The srpt pallas kernels run the reference step per event in the
    # interpreter, and the bitonic width Q dominates their cost — a
    # shorter batch and a bounded queue_cap keep those legs to seconds
    # while still covering both k values (a too-small cap raises
    # overflow, it never corrupts; the same cap goes to every engine so
    # the comparison stays apples-to-apples).
    srpt_batch = wl.sample_traces(400, 2, seed=17)
    checked = 0
    for policy in engines.policies_for("jax"):
        srpt = policy.endswith("srpt")
        b = srpt_batch if srpt else batch
        kw = {"queue_cap": 96} if srpt else {}
        ref = engines.simulate(policy, b, engine="jax", wl=wl, **kw)
        for eng in engines.engines_for(policy):
            if eng in ("jax", "python"):
                continue
            out = engines.simulate(policy, b, engine=eng, wl=wl, **kw)
            for f in ("response", "wait", "start", "blocked", "p_helper",
                      "p_routed", "preemptions"):
                a, b2 = getattr(out, f), getattr(ref, f)
                assert (a is None) == (b2 is None), (policy, eng, f)
                if a is not None:
                    assert np.array_equal(a, b2), (policy, eng, f)
            checked += 1
    assert checked >= 10   # 5 jax policies x {jax-shard, pallas}


def test_pallas_kernel_family_matches_refs_at_raw_stream_level():
    """Below the sim_batch wrappers: each msj_scan kernel against its ref
    (the scan core with the kernel call signature) on the raw outputs —
    including the BS event stream (tagged/rec_t/ovf) before the host
    scatter."""
    from repro.core.sim_jax import _bs_args
    from repro.kernels.msj_scan import (bs_scan, bs_scan_ref, fcfs_scan,
                                        fcfs_scan_ref, modbs_scan,
                                        modbs_scan_ref)

    wl = figure1_workload(32, theta=0.7)
    batch = wl.sample_traces(800, 2, seed=21)
    slots, s_max, h, q_cap = _bs_args(batch, None, wl, None)
    with enable_x64():
        a = jnp.asarray(batch.arrival, jnp.float64)
        c = jnp.asarray(batch.cls, jnp.int32)
        n = jnp.asarray(batch.need, jnp.int32)
        v = jnp.asarray(batch.service, jnp.float64)
        assert np.array_equal(np.asarray(fcfs_scan(a, n, v, k=batch.k)),
                              np.asarray(fcfs_scan_ref(a, n, v, k=batch.k)))
        out = modbs_scan(a, c, n, v, slots=slots, s_max=s_max, h=h)
        ref = modbs_scan_ref(a, c, n, v, slots=slots, s_max=s_max, h=h)
        for o, r in zip(out, ref):
            assert np.array_equal(np.asarray(o), np.asarray(r))
        out = bs_scan(a, c, n, v, slots=slots, s_max=s_max, h=h,
                      q_cap=q_cap)
        ref = bs_scan_ref(a, c, n, v, slots=slots, s_max=s_max, h=h,
                          q_cap=q_cap)
        for o, r in zip(out, ref):
            assert np.array_equal(np.asarray(o), np.asarray(r))


def test_pallas_single_trace_engines_match():
    """The engine knob on the single-trace wrappers routes to the kernels."""
    wl = figure1_workload(32, theta=0.7)
    trace = wl.sample_trace(600, seed=2)
    assert np.array_equal(fcfs_sim(trace, engine="pallas").response,
                          fcfs_sim(trace).response)
    assert np.array_equal(modified_bs_sim(trace, wl=wl,
                                          engine="pallas").response,
                          modified_bs_sim(trace, wl=wl).response)
    a = bs_sim(trace, wl=wl, engine="pallas")
    b = bs_sim(trace, wl=wl)
    assert np.array_equal(a.response, b.response)
    assert a.p_helper == b.p_helper


def test_unknown_engine_raises():
    wl = small_workload()
    batch = wl.sample_traces(10, 1, seed=0)
    with pytest.raises(ValueError, match="unknown engine"):
        fcfs_sim_batch(batch, engine="tpu")
    with pytest.raises(ValueError, match="unknown engine"):
        fcfs_sim(batch.rep(0), engine="")


# -- O(k) roll-and-insert under ties (property test) --------------------------
#
# Duplicated arrival/service values drive searchsorted(W, comp, "right")
# into tied boundaries (comp equal to one or more entries of W, tied
# arrivals, zero services).  The O(k) sorted-invariant step, the retained
# full-sort reference, and the fused Pallas kernel must agree bit-for-bit
# on every such trace.

_TIE_J = 64  # fixed length: one compile per k for all examples

tie_traces = st.tuples(
    st.sampled_from([8, 32]),                                  # k
    st.lists(st.tuples(st.sampled_from([0.0, 0.0, 0.25, 1.0]),  # gap
                       st.integers(1, 8),                       # need
                       st.sampled_from([0.0, 0.5, 0.5, 1.0, 2.0])),  # svc
             min_size=_TIE_J, max_size=_TIE_J),
)


@settings(max_examples=25, deadline=None)
@given(tie_traces)
def test_fcfs_roll_insert_ties_bitexact(args):
    k, jobs = args
    gaps = np.array([j[0] for j in jobs])
    need = np.minimum(np.array([j[1] for j in jobs]), k)
    svc = np.array([j[2] for j in jobs])
    arrival = np.cumsum(gaps)
    with enable_x64():
        a = jnp.asarray(arrival, jnp.float64)
        n = jnp.asarray(need, jnp.int32)
        v = jnp.asarray(svc, jnp.float64)
        fast = np.asarray(sim_jax._fcfs_scan(a, n, v, k))
        ref = np.asarray(sim_jax._fcfs_scan_reference(a, n, v, k))
        from repro.kernels.msj_scan import fcfs_scan
        fused = np.asarray(fcfs_scan(a[None], n[None], v[None], k=k)[0])
    assert np.array_equal(fast, ref), f"roll-and-insert != sort ref (k={k})"
    assert np.array_equal(fused, ref), f"pallas != sort ref (k={k})"


# -- stable bitonic rank/permute vs lax.sort (property test) ------------------
#
# The srpt pallas kernels rank and permute their slot tables with the
# bitonic network in kernels/msj_scan/sort.py instead of jax.lax.sort.
# Bit-equality with the *stable* lax.sort on adversarial keys — heavy
# duplicates, ±inf empty-slot sentinels, all-equal columns — is exactly
# what makes the fused kernels' queue permutation identical to the scan
# cores' and hence the whole sample path rtol=0.  The int payload column
# is a distinct per-element tag, so equality checks the full permutation,
# not just the sorted keys.

_SORT_R, _SORT_Q = 2, 24   # fixed non-pow2 width: exercises +inf padding

sort_cases = st.tuples(
    st.integers(1, 2),                                         # num_keys
    st.lists(st.tuples(
        st.sampled_from([-np.inf, np.inf, 0.0, 0.0, 1.0, 1.5, 2.5, 2.5]),
        st.sampled_from([0.0, 1.0, 1.0, 4.0])),                # tie-breaker
        min_size=_SORT_R * _SORT_Q, max_size=_SORT_R * _SORT_Q),
)


@settings(max_examples=25, deadline=None)
@given(sort_cases)
def test_bitonic_sort_bitexact_vs_stable_lax_sort(args):
    import jax

    from repro.kernels.msj_scan.sort import bitonic_sort

    num_keys, rows = args
    key = np.array([r[0] for r in rows]).reshape(_SORT_R, _SORT_Q)
    key2 = np.array([r[1] for r in rows]).reshape(_SORT_R, _SORT_Q)
    payload = np.arange(key.size, dtype=np.int32).reshape(key.shape)
    with enable_x64():
        ops = (jnp.asarray(key, jnp.float64),
               jnp.asarray(key2, jnp.float64),
               jnp.asarray(payload, jnp.int32))
        got = bitonic_sort(ops, num_keys=num_keys)
        want = jax.lax.sort(ops, dimension=-1, num_keys=num_keys,
                            is_stable=True)
    for g, w in zip(got, want):
        assert np.array_equal(np.asarray(g), np.asarray(w)), num_keys


def test_bitonic_sort_corner_cases():
    """Deterministic corners the sampler may miss: all-equal keys (pure
    stability — payload must come back verbatim), all-``+inf`` columns
    (indistinguishable from the pow2 padding), and widths on both sides
    of a power of two including the degenerate Q=1."""
    import jax

    from repro.kernels.msj_scan.sort import bitonic_sort

    with enable_x64():
        for Q in (1, 2, 7, 8, 9, 64):
            pay = jnp.arange(Q, dtype=jnp.int32)[None]
            for key in (np.zeros(Q),
                        np.full(Q, np.inf),
                        np.resize([np.inf, -np.inf, 0.0], Q)):
                ops = (jnp.asarray(key, jnp.float64)[None], pay)
                got = bitonic_sort(ops, num_keys=1)
                want = jax.lax.sort(ops, dimension=-1, num_keys=1,
                                    is_stable=True)
                for g, w in zip(got, want):
                    assert np.array_equal(np.asarray(g),
                                          np.asarray(w)), (Q, key[:3])
